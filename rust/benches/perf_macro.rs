//! Macro benchmarks: month-scale, memory-bounded simulation throughput.
//!
//! Where `perf_micro` times isolated hot paths, this group runs the
//! long-horizon scenarios the arena-retirement work exists for: a 30-day
//! background trace at 1× and (admission-capped) 4× load, and a
//! month-horizon multi-tenant campaign soak with driver-level job
//! retirement. Each case reports events/sec; the `meta` block records the
//! peak live-job counts and state-bytes estimates that make
//! memory-boundedness observable rather than asserted.
//!
//! Writes `BENCH_perf_macro.json` at the repo root so successive PRs can
//! diff the trajectory (`asa bench-diff`). `ASA_PERF_MACRO_DAYS` overrides
//! the horizon (CI smoke uses 3); labels are horizon-independent so
//! items/sec stays comparable across overrides.

use asa::experiments::campaign::Strategy;
use asa::experiments::concurrent::{run_concurrent, ConcurrentOpts, TenantStrategy};
use asa::experiments::fleet::{run_fleet, FleetOpts};
use asa::simulator::{Simulator, SystemConfig};
use asa::util::bench::Bench;
use asa::Time;

fn horizon_days() -> i64 {
    std::env::var("ASA_PERF_MACRO_DAYS")
        .ok()
        .and_then(|s| s.trim().parse::<i64>().ok())
        .filter(|&d| d > 0)
        .unwrap_or(30)
}

struct TraceStats {
    events: u64,
    live_jobs_peak: u64,
    registered: u64,
    rejected: u64,
    memory_bytes: usize,
}

fn background_trace(cfg: &SystemConfig, horizon: Time) -> TraceStats {
    let mut sim = Simulator::new(cfg.clone(), 42);
    sim.run_until(horizon);
    TraceStats {
        events: sim.metrics.events,
        live_jobs_peak: sim.metrics.live_jobs_peak,
        registered: sim.jobs_registered(),
        rejected: sim.metrics.rejected,
        memory_bytes: sim.memory_bytes_estimate(),
    }
}

/// 4× offered load with a Slurm-style MaxJobCount admission cap: the queue
/// (and with it the live-job set and per-pass cost) stays bounded even
/// though the machine can never drain the offered work.
fn overloaded(mut cfg: SystemConfig) -> SystemConfig {
    cfg.workload.target_load *= 4.0;
    cfg.workload.max_queued_jobs = 2_000;
    cfg
}

fn month_campaign(horizon: Time) -> ConcurrentOpts {
    ConcurrentOpts {
        tenants: 6,
        per_tenant: 4,
        mean_gap: 600, // overridden by horizon
        scale: 112,
        strategy: TenantStrategy::Uniform(Strategy::Asa),
        seed: 42,
        settle: 0,
        baseline: false,
        horizon,
        retire: true,
    }
}

fn main() {
    let days = horizon_days();
    let horizon: Time = days * 24 * 3600;
    let mut b = Bench::new("perf_macro");
    b.root_json = true;
    b.samples = 2;
    b.budget_secs = 0.0;
    b.meta("horizon_days", days);

    // 1) Month of background churn at nominal load (items = engine events).
    // Gauges come from the warmup invocation — the sims are seeded, so
    // every iteration reproduces the same counts; no extra gauge-only run.
    let hpc2n = SystemConfig::hpc2n();
    let mut gauges: Option<TraceStats> = None;
    b.case_throughput_of("sim: hpc2n background 1x (macro horizon)", || {
        let s = background_trace(&hpc2n, horizon);
        let events = s.events;
        gauges.get_or_insert(s);
        events
    });
    let s = gauges.take().expect("warmup ran");
    b.meta("hpc2n_1x_live_jobs_peak", s.live_jobs_peak as i64);
    b.meta("hpc2n_1x_jobs_registered", s.registered as i64);
    b.meta("hpc2n_1x_memory_bytes", s.memory_bytes);

    let uppmax = SystemConfig::uppmax();
    let mut gauges: Option<TraceStats> = None;
    b.case_throughput_of("sim: uppmax background 1x (macro horizon)", || {
        let s = background_trace(&uppmax, horizon);
        let events = s.events;
        gauges.get_or_insert(s);
        events
    });
    let s = gauges.take().expect("warmup ran");
    b.meta("uppmax_1x_live_jobs_peak", s.live_jobs_peak as i64);
    b.meta("uppmax_1x_jobs_registered", s.registered as i64);
    b.meta("uppmax_1x_memory_bytes", s.memory_bytes);

    // 2) Partitioned two-centre domain: the cori/abisko split runs one
    // scheduling pass + EASY shadow per partition over a shared event
    // loop — this case tracks the per-pass cost of the partitioned path
    // at the same month horizon as the flat machines above.
    let twoc = SystemConfig::two_center();
    let mut gauges: Option<TraceStats> = None;
    b.case_throughput_of("sim: two-center partitioned background 1x (macro horizon)", || {
        let s = background_trace(&twoc, horizon);
        let events = s.events;
        gauges.get_or_insert(s);
        events
    });
    let s = gauges.take().expect("warmup ran");
    b.meta("two_center_live_jobs_peak", s.live_jobs_peak as i64);
    b.meta("two_center_jobs_registered", s.registered as i64);
    b.meta("two_center_memory_bytes", s.memory_bytes);

    // 3) 4× overload with admission cap: live jobs must stay bounded by
    // cap + machine occupancy, not by total submissions.
    let hot = overloaded(SystemConfig::hpc2n());
    let mut gauges: Option<TraceStats> = None;
    b.case_throughput_of("sim: hpc2n background 4x capped (macro horizon)", || {
        let s = background_trace(&hot, horizon);
        let events = s.events;
        gauges.get_or_insert(s);
        events
    });
    let s = gauges.take().expect("warmup ran");
    assert!(s.rejected > 0, "4x load must exercise the admission cap");
    b.meta("hpc2n_4x_live_jobs_peak", s.live_jobs_peak as i64);
    b.meta("hpc2n_4x_jobs_registered", s.registered as i64);
    b.meta("hpc2n_4x_rejected", s.rejected as i64);
    b.meta("hpc2n_4x_memory_bytes", s.memory_bytes);

    // 4) Month-horizon multi-tenant campaign: 24 ASA workflows spread over
    // the window on the live hpc2n queue, completed workflows retired.
    let opts = month_campaign(horizon);
    let mut report = None;
    b.case_throughput_of("campaign: month-horizon concurrent soak", || {
        let r = run_concurrent(&hpc2n, &opts);
        let events = r.sim_events;
        report.get_or_insert(r);
        events
    });
    let report = report.take().expect("warmup ran");
    b.meta("campaign_live_jobs_peak", report.live_jobs_peak as i64);
    b.meta("campaign_jobs_registered", report.total_registered as i64);
    b.meta("campaign_memory_bytes", report.memory_bytes);

    // 5) Fleet month soak: two federated centres (hpc2n + uppmax) each
    // running their own background trace over the macro horizon, with 24
    // routed workflows spread across the window and completed workflows
    // retired. The headline gauges are the fleet-wide live-job peak and
    // state-bytes estimate — both must stay flat in the horizon, not grow
    // with the ~10^6 total jobs registered across the fleet.
    let fopts = FleetOpts {
        centers: 2,
        systems: vec!["hpc2n".to_string(), "uppmax".to_string()],
        workflows: 24,
        scale: 112,
        strategy: Strategy::Asa,
        seed: 42,
        settle: 0,
        horizon,
        epochs: 6,
        retire: true,
        ..FleetOpts::default()
    };
    let mut freport = None;
    b.case_throughput_of("fleet: month-horizon 2-center soak", || {
        let r = run_fleet(&fopts);
        let events = r.sim_events;
        freport.get_or_insert(r);
        events
    });
    let freport = freport.take().expect("warmup ran");
    b.meta("fleet_live_jobs_peak", freport.live_jobs_peak as i64);
    b.meta("fleet_jobs_registered", freport.total_registered as i64);
    b.meta("fleet_memory_bytes", freport.memory_bytes);
    for c in &freport.centers {
        b.meta(&format!("fleet_{}_routed", c.tag), c.routed as i64);
    }

    b.finish();
}
