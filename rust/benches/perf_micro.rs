//! Micro-benchmarks for the hot paths (the §Perf iteration log targets):
//! the scheduling pass, the simulator event loop under background load,
//! and the ASA update under both kernel backends.
use asa::coordinator::actions::ActionGrid;
use asa::coordinator::kernel::{PureRustKernel, UpdateKernel};
use asa::simulator::{Simulator, SystemConfig};
use asa::util::bench::Bench;
use asa::util::rng::Rng;

fn main() {
    let mut b = Bench::new("perf_micro");

    // 1) Simulator throughput: 24 h of HPC2n background churn.
    b.samples = 5;
    b.case("sim: 24h hpc2n background", || {
        let mut sim = Simulator::new(SystemConfig::hpc2n(), 42);
        sim.run_until(24 * 3600);
        sim.metrics.started
    });
    b.case("sim: 24h uppmax background", || {
        let mut sim = Simulator::new(SystemConfig::uppmax(), 42);
        sim.run_until(24 * 3600);
        sim.metrics.started
    });

    // 2) ASA update kernel: single rows and batches.
    let grid = ActionGrid::paper();
    let m = grid.len();
    let mut rng = Rng::new(1);
    let mk_row = |rng: &mut Rng| -> Vec<f64> {
        let mut p: Vec<f64> = (0..m).map(|_| rng.uniform(1e-4, 1.0)).collect();
        let s: f64 = p.iter().sum();
        p.iter_mut().for_each(|x| *x /= s);
        p
    };
    let loss: Vec<f64> = (0..m).map(|i| if i == 7 { 0.0 } else { 1.0 }).collect();

    let mut pure = PureRustKernel;
    let row = mk_row(&mut rng);
    b.case_throughput("kernel pure-rust: 10k single updates", 10_000, || {
        let mut p = row.clone();
        for _ in 0..10_000 {
            pure.update(&mut p, &loss, 0.3);
        }
        p[0]
    });

    let rows = 64;
    let mut batch: Vec<f64> = Vec::new();
    for _ in 0..rows {
        batch.extend(mk_row(&mut rng));
    }
    let losses: Vec<f64> = (0..rows).flat_map(|_| loss.clone()).collect();
    let gammas = vec![0.3; rows];
    b.case_throughput("kernel pure-rust: 64-row batch x100", 6_400, || {
        let mut p = batch.clone();
        for _ in 0..100 {
            pure.update_batch(m, &mut p, &losses, &gammas);
        }
        p[0]
    });

    if let Ok(mut xla) = asa::runtime::XlaKernel::load_default(grid.values()) {
        b.samples = 3;
        b.case_throughput("kernel aot-f32: 100 single updates", 100, || {
            let mut p = row.clone();
            for _ in 0..100 {
                xla.update(&mut p, &loss, 0.3);
            }
            p[0]
        });
        b.case_throughput("kernel aot-f32: 64-row batch x100", 6_400, || {
            let mut p = batch.clone();
            for _ in 0..100 {
                xla.update_batch(m, &mut p, &losses, &gammas);
            }
            p[0]
        });
    }
    b.finish();
}
