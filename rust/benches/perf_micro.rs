//! Micro-benchmarks for the hot paths (the §Perf iteration log targets):
//! the scheduling pass, the simulator event loop under background load,
//! deep/dependency-heavy queues, and the ASA update under both kernel
//! backends. Writes `BENCH_perf_micro.json` at the repo root so successive
//! PRs can diff the perf trajectory.
use asa::coordinator::actions::ActionGrid;
use asa::coordinator::kernel::{PureRustKernel, UpdateKernel};
use asa::simulator::{Dependency, FaultPlan, JobSpec, PartitionId, Simulator, SystemConfig};
use asa::util::bench::Bench;
use asa::util::rng::Rng;

/// Deep-queue regression: `held` jobs sit parked behind a long-running
/// dependency gate while a churn stream of short jobs forces a scheduling
/// pass per event. With the incremental engine the per-pass cost tracks
/// the *eligible* set (the churn), not the parked total, so the 10k case
/// should cost about the same as the 1k case.
fn deep_queue(held: usize) -> u64 {
    let mut sim = Simulator::new_empty(SystemConfig::testbed(64, 28));
    let gate = sim.submit(JobSpec::new(1, "gate", 1, 1_000_000).with_limit(1_000_000));
    for i in 0..held {
        sim.submit(
            JobSpec::new(2 + (i % 50) as u32, format!("h{i}"), 4, 60)
                .with_dependency(Dependency::AfterOk(vec![gate])),
        );
    }
    for k in 0..2000u32 {
        sim.submit_at(
            k as i64 * 30,
            JobSpec::new(60 + k % 20, format!("c{k}"), 8, 25),
        );
    }
    sim.run_until(2000 * 30);
    sim.metrics.passes
}

/// Dependency-heavy chain + fan-out: a 300-deep `AfterOk` chain and a
/// 500-wide fan-out behind one root, exercising the reverse-dependency
/// wakeup path on every completion.
fn dep_web() -> u64 {
    let mut sim = Simulator::new_empty(SystemConfig::testbed(64, 28));
    let mut prev = sim.submit(JobSpec::new(1, "c0", 2, 20));
    for i in 1..300u32 {
        prev = sim.submit(
            JobSpec::new(1, format!("c{i}"), 2, 20)
                .with_dependency(Dependency::AfterOk(vec![prev])),
        );
    }
    let root = sim.submit(JobSpec::new(2, "root", 2, 30));
    for i in 0..500u32 {
        sim.submit(
            JobSpec::new(3 + i % 40, format!("f{i}"), 2, 15)
                .with_dependency(Dependency::AfterOk(vec![root])),
        );
    }
    while sim.step().is_some() {}
    sim.metrics.completed
}

/// Same-tick batching: waves of identical jobs all start together and all
/// finish at the same instant, so each wave's submissions and completions
/// drain as single ticks (one scheduling pass each) instead of one pass
/// per event.
fn finish_storm() -> u64 {
    let mut sim = Simulator::new_empty(SystemConfig::testbed(64, 28));
    for wave in 0..50i64 {
        for j in 0..400u32 {
            sim.submit_at(
                wave * 1_000,
                JobSpec::new(1 + j % 32, format!("w{wave}j{j}"), 4, 600),
            );
        }
    }
    sim.run_until(51_000);
    sim.metrics.passes
}

/// Thread-scaling probe: two saturated partitions, each with a 600-deep
/// eligible queue (well past the parallel-pass candidate threshold), and a
/// churn stream forcing a scheduling pass per tick. With `threads > 1` the
/// per-partition priority+EASY passes run concurrently; the committed
/// event stream is bit-identical either way (proptest-pinned), so the
/// returned pass count matches across thread counts.
fn partitioned_pass(threads: usize) -> u64 {
    let mut sim = Simulator::new_empty(SystemConfig::testbed_partitioned(64, 28));
    sim.set_pass_threads(threads);
    for p in 0..2usize {
        for i in 0..600u32 {
            sim.submit(
                JobSpec::new(1 + i % 50, format!("p{p}q{i}"), 56, 3_000)
                    .with_partition(PartitionId(p as u32)),
            );
        }
    }
    for k in 0..400u32 {
        sim.submit_at(
            k as i64 * 30,
            JobSpec::new(60 + k % 20, format!("c{k}"), 4, 25)
                .with_partition(PartitionId(k % 2)),
        );
    }
    sim.run_until(400 * 30);
    sim.metrics.passes
}

/// Fault-layer hot path: 24 h of HPC2n background churn under a stochastic
/// node-failure/repair process (MTBF 1 h, MTTR 10 min, 256 cores per
/// failure). Every failure terminates victims off the packed machine
/// (largest planned end first) and every capacity change forces a pass —
/// the cost of `victims_desc` + `shrink`/`grow` on a production-sized
/// `by_end` index is what this case tracks.
fn failure_storm() -> u64 {
    let mut sim = Simulator::new(SystemConfig::hpc2n(), 42);
    sim.set_fault_plan(FaultPlan::stochastic(7, 24 * 3600, 1, 256, 3_600.0, 600.0));
    sim.run_until(24 * 3600);
    sim.metrics.started
}

fn background_churn(system: SystemConfig, horizon_secs: i64) -> u64 {
    let mut sim = Simulator::new(system, 42);
    sim.run_until(horizon_secs);
    sim.metrics.started
}

fn main() {
    let mut b = Bench::new("perf_micro");
    b.root_json = true;

    // 1) Simulator throughput: 24 h of HPC2n background churn (items =
    // jobs started, taken from the warmup run — the sims are seeded, so
    // every iteration starts the same count).
    b.samples = 5;
    b.case_throughput_of("sim: 24h hpc2n background", || {
        background_churn(SystemConfig::hpc2n(), 24 * 3600)
    });
    b.case_throughput_of("sim: 24h uppmax background", || {
        background_churn(SystemConfig::uppmax(), 24 * 3600)
    });

    // 1b) Deep queues: pass cost must not scale with dependency-parked
    // jobs (items = scheduling passes run).
    b.samples = 3;
    b.case_throughput_of("sim: deep queue 1k dep-held, 2k churn", || deep_queue(1_000));
    b.case_throughput_of("sim: deep queue 10k dep-held, 2k churn", || deep_queue(10_000));
    b.case_throughput_of("sim: dep chain 300 + fanout 500", dep_web);
    b.case_throughput_of("sim: same-tick finish storm", finish_storm);
    b.case_throughput_of("sim: node-failure storm (24h hpc2n)", failure_storm);

    // 1b'') Checkpoint path: serialize + restore a production-sized
    // simulator (24 h of HPC2n churn, built once outside the timer).
    // Items = snapshot bytes, so the rate reads as checkpoint bytes/sec
    // for a full save+restore round trip.
    let mut snap_sim = Simulator::new(SystemConfig::hpc2n(), 42);
    snap_sim.run_until(24 * 3600);
    b.case_throughput_of("sim: snapshot save+restore (24h hpc2n)", || {
        let snap = snap_sim.save_snapshot();
        let restored = Simulator::restore_snapshot(&snap, SystemConfig::hpc2n())
            .expect("bench snapshot restores");
        assert_eq!(restored.now(), snap_sim.now());
        snap.len() as u64
    });

    // 1b') Thread scaling: the same two-partition deep-queue scenario at
    // 1 thread vs N — `asa bench-summary` pairs the `[1 thread]` /
    // `[N threads]` labels into a speedup-vs-1-thread column.
    let n_threads = asa::util::par::default_threads().max(2);
    b.case_throughput_of("sim: two-center pass [1 thread]", || partitioned_pass(1));
    b.case_throughput_of(&format!("sim: two-center pass [{n_threads} threads]"), || {
        partitioned_pass(n_threads)
    });

    // 1c) Long-horizon churn: one week of HPC2n background load, with the
    // arena-boundedness gauges captured from the (seeded, reproducible)
    // warmup run rather than an extra gauge-only simulation.
    b.samples = 1;
    let mut gauges: Option<(u64, u64, usize)> = None;
    b.case_throughput_of("sim: 7d hpc2n background", || {
        let mut sim = Simulator::new(SystemConfig::hpc2n(), 42);
        sim.run_until(7 * 24 * 3600);
        gauges.get_or_insert((
            sim.metrics.live_jobs_peak,
            sim.jobs_registered(),
            sim.memory_bytes_estimate(),
        ));
        sim.metrics.started
    });
    let (live_peak, registered, bytes) = gauges.take().expect("warmup ran");
    b.meta("hpc2n_7d_live_jobs_peak", live_peak as i64);
    b.meta("hpc2n_7d_jobs_registered", registered as i64);
    b.meta("hpc2n_7d_memory_bytes", bytes);

    // 2) ASA update kernel: single rows and batches.
    b.samples = 5;
    let grid = ActionGrid::paper();
    let m = grid.len();
    let mut rng = Rng::new(1);
    let mk_row = |rng: &mut Rng| -> Vec<f64> {
        let mut p: Vec<f64> = (0..m).map(|_| rng.uniform(1e-4, 1.0)).collect();
        let s: f64 = p.iter().sum();
        p.iter_mut().for_each(|x| *x /= s);
        p
    };
    let loss: Vec<f64> = (0..m).map(|i| if i == 7 { 0.0 } else { 1.0 }).collect();

    let mut pure = PureRustKernel;
    let row = mk_row(&mut rng);
    b.case_throughput("kernel pure-rust: 10k single updates", 10_000, || {
        let mut p = row.clone();
        for _ in 0..10_000 {
            pure.update(&mut p, &loss, 0.3);
        }
        p[0]
    });

    let rows = 64;
    let mut batch: Vec<f64> = Vec::new();
    for _ in 0..rows {
        batch.extend(mk_row(&mut rng));
    }
    let losses: Vec<f64> = (0..rows).flat_map(|_| loss.clone()).collect();
    let gammas = vec![0.3; rows];
    b.case_throughput("kernel pure-rust: 64-row batch x100", 6_400, || {
        let mut p = batch.clone();
        for _ in 0..100 {
            pure.update_batch(m, &mut p, &losses, &gammas);
        }
        p[0]
    });

    if let Ok(mut xla) = asa::runtime::XlaKernel::load_default(grid.values()) {
        b.samples = 3;
        b.case_throughput("kernel aot-f32: 100 single updates", 100, || {
            let mut p = row.clone();
            for _ in 0..100 {
                xla.update(&mut p, &loss, 0.3);
            }
            p[0]
        });
        b.case_throughput("kernel aot-f32: 64-row batch x100", 6_400, || {
            let mut p = batch.clone();
            for _ in 0..100 {
                xla.update_batch(m, &mut p, &losses, &gammas);
            }
            p[0]
        });
    }
    b.finish();
}
