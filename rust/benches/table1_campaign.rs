//! Bench: regenerate Table 1 (the full 54-run strategy comparison).
use asa::experiments::campaign::{self, SCALINGS};
use asa::util::bench::Bench;

fn main() {
    let mut b = Bench::new("table1_campaign");
    b.samples = 3;
    b.budget_secs = 30.0;
    b.case("table1: 54 runs (3 wf x 6 scalings x 3 strategies)", || {
        campaign::run_campaign(&["montage", "blast", "statistics"], &SCALINGS, false, 42)
    });
    let cells =
        campaign::run_campaign(&["montage", "blast", "statistics"], &SCALINGS, false, 42);
    println!("{}", campaign::table1(&cells).render());
    b.finish();
}
