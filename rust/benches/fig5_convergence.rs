//! Bench: regenerate Fig. 5 (convergence simulation, 3 policies × 1000
//! iterations) and time it under both kernel backends.
use asa::coordinator::kernel::PureRustKernel;
use asa::experiments::convergence;
use asa::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig5_convergence");
    b.case("fig5 1000 iters x 3 policies (pure-rust)", || {
        let mut k = PureRustKernel;
        convergence::run(1000, 5, &mut k)
    });
    if let Ok(mut xla) = asa::runtime::XlaKernel::load_default(
        asa::coordinator::actions::ActionGrid::paper().values(),
    ) {
        b.samples = 3;
        b.case("fig5 1000 iters x 3 policies (aot-f32)", || {
            convergence::run(1000, 5, &mut xla)
        });
    }
    // Regenerate and print the actual figure once (parallel path: one
    // worker per policy, bit-identical to the serial run above).
    let mut k = PureRustKernel;
    let r = convergence::run_par(1000, 5);
    println!("{}", r.summary().render());

    // Ablation (paper §4.5): the tuned policy's repetition parameter trades
    // re-convergence speed against following the last observation too
    // eagerly. Measured as total loss over the Fig.-5 scenario.
    use asa::coordinator::asa::{AsaConfig, AsaEstimator};
    use asa::coordinator::policy::Policy;
    use asa::util::rng::Rng;
    println!("
ablation: tuned repetition parameter (total 0/1 loss, 1000 iters)");
    for rep in [1u32, 10, 50, 200] {
        let mut total = 0.0;
        for seed in [5u64, 6, 7] {
            let mut est = AsaEstimator::new(AsaConfig {
                policy: Policy::Tuned { rep },
                ..AsaConfig::default()
            });
            let mut rng = Rng::new(seed ^ 0xbeef);
            let mut truth_rng = Rng::new(seed);
            let levels: Vec<i64> = (0..5)
                .map(|_| truth_rng.uniform(30f64.ln(), 60_000f64.ln()).exp() as i64)
                .collect();
            for i in 0..1000usize {
                let w = levels[(i / 200).min(4)];
                let (a, _) = est.sample_wait(&mut rng);
                total += est.observe(a, w, &mut k, &mut rng);
            }
        }
        println!("  rep={rep:<4} mean total loss {:.1}", total / 3.0);
    }
    b.finish();
}
