//! Standalone queue-wait estimation service — ASA's estimator as a library,
//! fed by live observations, with the AOT-compiled XLA kernel on the hot
//! path when artifacts are available.
//!
//! Demonstrates: per-geometry stores, JSON persistence across "sessions",
//! the XLA/pure-rust backend swap, and prediction-accuracy accounting.
//!
//! ```bash
//! make artifacts && cargo run --release --example queue_estimator
//! ```

use asa::coordinator::actions::ActionGrid;
use asa::coordinator::asa::AsaConfig;
use asa::coordinator::kernel::{PureRustKernel, UpdateKernel};
use asa::coordinator::state::{AsaStore, GeometryKey};
use asa::runtime::XlaKernel;
use asa::simulator::{JobSpec, SimEvent, Simulator, SystemConfig};
use asa::util::rng::Rng;

fn main() {
    // Prefer the AOT XLA artifact; fall back to pure rust.
    let mut kernel: Box<dyn UpdateKernel> =
        match XlaKernel::load_default(ActionGrid::paper().values()) {
            Ok(k) => {
                println!("backend: XLA/PJRT (AOT artifact)");
                Box::new(k)
            }
            Err(e) => {
                println!("backend: pure-rust ({e})");
                Box::new(PureRustKernel)
            }
        };

    let mut sim = Simulator::new(SystemConfig::uppmax(), 77);
    sim.run_until(12 * 3600);
    let mut store = AsaStore::new(AsaConfig::default());
    let mut rng = Rng::new(1);
    let key = GeometryKey::new("uppmax", 320);

    println!("\nfeeding 30 live observations of geometry uppmax:320 ...");
    let mut hits = 0;
    for i in 0..30 {
        let (action, predicted) = store.estimator(&key).sample_wait(&mut rng);
        let id = sim.submit(JobSpec::new(9, format!("probe{i}"), 320, 1200));
        let wait = loop {
            match sim.step() {
                Some(SimEvent::Started { id: sid, time }) if sid == id => {
                    break time - sim.job(id).submit_time;
                }
                Some(_) => {}
                None => unreachable!("background trace never ends"),
            }
        };
        store
            .estimator(&key)
            .observe(action, wait, kernel.as_mut(), &mut rng);
        if predicted <= wait {
            hits += 1;
        }
        sim.cancel(id);
        sim.run_until(sim.now() + 600);
        if (i + 1) % 10 == 0 {
            println!(
                "  after {:>2} obs: expected wait {:>7.0} s, mode {:>6} s, hit ratio {:.0}%",
                i + 1,
                store.estimator(&key).expected_wait(),
                store.estimator(&key).best_wait(),
                100.0 * hits as f64 / (i + 1) as f64
            );
        }
    }

    // Persist learned state; a later session restores it instantly.
    let path = std::env::temp_dir().join("asa-estimator-state.json");
    store.save_file(&path).expect("save state");
    let (restored, errors) = AsaStore::load_file(AsaConfig::default(), &path).expect("load");
    assert!(errors.is_empty());
    println!(
        "\nstate saved to {} and restored: {} geometries, {} observations",
        path.display(),
        restored.len(),
        restored.get(&key).map(|e| e.observations()).unwrap_or(0)
    );
}
