//! The paper's Montage story end-to-end (the workload its intro motivates):
//! run Montage at every scaling on both systems under all three strategies
//! — plus ASA-Naïve at HPC2n@112, the paper's §4.5 sensitivity case — and
//! print the makespan/usage tradeoff.
//!
//! ```bash
//! cargo run --release --example montage_campaign
//! ```

use asa::coordinator::asa::AsaConfig;
use asa::coordinator::kernel::PureRustKernel;
use asa::coordinator::policy::Policy;
use asa::coordinator::state::AsaStore;
use asa::experiments::campaign::{run_session, Strategy, SCALINGS};
use asa::simulator::SystemConfig;
use asa::util::table::Table;

fn main() {
    let mut table = Table::new([
        "system", "cores", "strategy", "TWT (s)", "makespan (s)", "core-hours",
    ]);
    for &(sys_name, scale) in &SCALINGS {
        let system = SystemConfig::by_name(sys_name).unwrap();
        let mut store = AsaStore::new(AsaConfig {
            policy: Policy::Tuned { rep: 50 },
            ..AsaConfig::default()
        });
        let mut kernel = PureRustKernel;
        let seed = 42 ^ (scale as u64) << 8;
        let mut strategies = vec![Strategy::BigJob, Strategy::PerStage, Strategy::Asa];
        // §4.5: the no-dependency sensitivity case at HPC2n@112.
        if sys_name == "hpc2n" && scale == 112 {
            strategies.push(Strategy::AsaNaive);
        }
        for strategy in strategies {
            if matches!(strategy, Strategy::Asa | Strategy::AsaNaive) {
                // Warm-up (state is kept across runs, §4.3).
                run_session(
                    &system, scale, Strategy::Asa, &["montage"], seed ^ 0xdead,
                    &mut store, &mut kernel,
                );
            }
            let cells = run_session(
                &system, scale, strategy, &["montage"], seed, &mut store, &mut kernel,
            );
            let run = &cells[0].run;
            table.row([
                sys_name.to_string(),
                format!("{scale}"),
                run.strategy.clone(),
                format!("{}", run.total_wait()),
                format!("{}", run.makespan()),
                format!("{:.1}", run.core_hours()),
            ]);
            if let Some(stats) = &cells[0].asa_stats {
                if stats.resubmissions > 0 {
                    println!(
                        "  note: {} @ {scale} [{}] cancelled+resubmitted {} stage job(s), {:.1} core-h overhead",
                        sys_name,
                        run.strategy,
                        stats.resubmissions,
                        stats.overhead_core_secs as f64 / 3600.0
                    );
                }
            }
        }
        table.sep();
    }
    println!("\nMontage campaign (Fig. 6 data):\n{}", table.render());
    println!(
        "Expected shape: Per-Stage minimises core-hours but inflates TWT/makespan\n\
         as the scaling grows; ASA keeps Per-Stage's charge at close to Big-Job's\n\
         makespan; Naïve mode pays cancel+resubmit overheads."
    );
}
