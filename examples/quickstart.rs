//! Quickstart: learn a queue's waiting time and schedule one workflow
//! proactively — the smallest end-to-end use of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use asa::coordinator::asa::AsaConfig;
use asa::coordinator::kernel::PureRustKernel;
use asa::coordinator::policy::Policy;
use asa::coordinator::state::AsaStore;
use asa::coordinator::strategy::{run_asa, AsaRunOpts};
use asa::simulator::{Simulator, SystemConfig};
use asa::util::rng::Rng;
use asa::workflow::{apps, wms};

fn main() {
    // A live cluster: HPC2n's geometry with its production-like background
    // workload already churning.
    let system = SystemConfig::hpc2n();
    let mut sim = Simulator::new(system, 42);
    sim.run_until(6 * 3600); // let the machine settle

    let wf = apps::montage();
    let scale = 112;
    println!("workflow: {} @ {scale} cores on {}", wf.name, sim.config().name);

    // Baseline 1: one big allocation for the whole workflow.
    let big = wms::run_big_job(&mut sim, 7, &wf, scale);
    // Baseline 2: one right-sized allocation per stage (E-HPC).
    let per = wms::run_per_stage(&mut sim, 7, &wf, scale);

    // ASA: proactive per-stage submission with learned wait estimates.
    let mut store = AsaStore::new(AsaConfig {
        policy: Policy::Tuned { rep: 50 },
        ..AsaConfig::default()
    });
    let mut kernel = PureRustKernel;
    let mut rng = Rng::new(7);
    let (asa_run, stats) = run_asa(
        &mut sim,
        7,
        &wf,
        scale,
        &mut store,
        &mut kernel,
        &mut rng,
        &AsaRunOpts::default(),
    );

    println!("\n{:<10} {:>12} {:>10} {:>12}", "strategy", "makespan (s)", "TWT (s)", "core-hours");
    for run in [&big, &per, &asa_run] {
        println!(
            "{:<10} {:>12} {:>10} {:>12.1}",
            run.strategy,
            run.makespan(),
            run.total_wait(),
            run.core_hours()
        );
    }
    println!(
        "\nASA made {} predictions ({} resubmissions, {:.1} core-h overhead)",
        stats.predictions.len(),
        stats.resubmissions,
        stats.overhead_core_secs as f64 / 3600.0
    );
    // The headline tradeoff: ASA's core-hours ≈ Per-Stage's, while its
    // makespan stays close to Big Job's.
}
