//! Bring-your-own-cluster: define a system in JSON, run ASA on it, and
//! compare the plain estimator against the §6 future-work extension
//! (queue-state-conditioned estimation).
//!
//! ```bash
//! cargo run --release --example custom_cluster [path/to/system.json]
//! ```

use asa::coordinator::asa::{AsaConfig, AsaEstimator};
use asa::coordinator::contextual::{ContextualEstimator, QueueState};
use asa::coordinator::kernel::PureRustKernel;
use asa::coordinator::policy::Policy;
use asa::simulator::config::resolve_system;
use asa::simulator::{JobSpec, SimEvent, Simulator};
use asa::util::rng::Rng;

const DEMO_CONFIG: &str = r#"{
  "name": "demo-cluster",
  "nodes": 64, "cores_per_node": 32,
  "scheduler": {"backfill_depth": 200},
  "workload": {
    "target_load": 1.02, "burstiness": 0.6,
    "regime_period": 7200, "regime_lo": 0.5, "regime_hi": 1.6,
    "user_pool": 40, "backlog_factor": 1.0, "initial_user_usage": 5e6,
    "classes": [
      {"weight": 0.7, "cores_lo": 1,  "cores_hi": 32,  "runtime_mu": 7.0, "runtime_sigma": 1.0},
      {"weight": 0.3, "cores_lo": 32, "cores_hi": 512, "runtime_mu": 9.0, "runtime_sigma": 0.8}
    ]
  }
}"#;

fn main() {
    let spec = std::env::args().nth(1);
    let system = match &spec {
        Some(path) => resolve_system(path).expect("config load failed"),
        None => {
            let tmp = std::env::temp_dir().join("asa-demo-system.json");
            std::fs::write(&tmp, DEMO_CONFIG).unwrap();
            resolve_system(tmp.to_str().unwrap()).unwrap()
        }
    };
    println!(
        "system {}: {} nodes × {} cores = {} cores",
        system.name,
        system.nodes,
        system.cores_per_node,
        system.total_cores()
    );

    let mut sim = Simulator::new(system, 11);
    sim.run_until(4 * 3600);

    let cfg = AsaConfig {
        policy: Policy::Tuned { rep: 50 },
        ..AsaConfig::default()
    };
    let mut flat = AsaEstimator::new(cfg.clone());
    let mut ctx = ContextualEstimator::new(cfg);
    let mut kernel = PureRustKernel;
    let mut rng = Rng::new(5);

    // Feed both estimators the same live observations of a 64-core probe.
    let mut flat_loss = 0.0;
    let mut ctx_loss = 0.0;
    let n = 50;
    for i in 0..n {
        let state = QueueState {
            depth: sim.queue_depth(),
            utilization: sim.cluster().utilization(),
        };
        let (fa, _) = flat.sample_wait(&mut rng);
        let (ca, _) = ctx.sample_wait(state, &mut rng);
        let id = sim.submit(JobSpec::new(9, format!("probe{i}"), 64, 900));
        let wait = loop {
            match sim.step() {
                Some(SimEvent::Started { id: sid, time }) if sid == id => {
                    break time - sim.job(id).submit_time
                }
                Some(_) => {}
                None => unreachable!(),
            }
        };
        sim.cancel(id);
        flat_loss += flat.observe(fa, wait, &mut kernel, &mut rng);
        ctx_loss += ctx.observe(state, ca, wait, &mut kernel, &mut rng);
        sim.run_until(sim.now() + 1200);
    }

    println!("\nafter {n} observations of geometry {}:64", sim.config().name);
    println!(
        "  unconditioned ASA: expected wait {:>7.0} s, total 0/1 loss {flat_loss:.0}",
        flat.expected_wait()
    );
    let state = QueueState {
        depth: sim.queue_depth(),
        utilization: sim.cluster().utilization(),
    };
    println!(
        "  contextual ASA:    expected wait {:>7.0} s (for the current queue state), \
         total 0/1 loss {ctx_loss:.0}, {} context bank(s) populated",
        ctx.expected_wait(state),
        ctx.populated_banks()
    );
}
