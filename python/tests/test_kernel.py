"""L1 correctness: the Pallas kernels against the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes-compatible value ranges and gamma scales;
every case must match ``ref.py`` to f32 tolerance.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import asa_update as k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def normalise(rows):
    rows = np.asarray(rows, dtype=np.float32)
    return rows / rows.sum(axis=-1, keepdims=True)


def random_case(rng, b, m):
    p = normalise(rng.uniform(1e-5, 1.0, size=(b, m)))
    loss = rng.uniform(0.0, 1.0, size=(b, m)).astype(np.float32)
    gamma = rng.uniform(0.01, 3.0, size=(b,)).astype(np.float32)
    return jnp.array(p), jnp.array(loss), jnp.array(gamma)


@pytest.mark.parametrize("b,m,block", [(1, 53, 1), (8, 53, 8), (64, 53, 8), (8, 16, 8)])
def test_update_matches_ref(b, m, block):
    rng = np.random.default_rng(b * 100 + m)
    p, loss, gamma = random_case(rng, b, m)
    got = k.asa_update(p, loss, gamma, block_b=block)
    want = ref.asa_update_ref(p, loss, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,m", [(8, 53), (64, 53)])
def test_update_rows_sum_to_one(b, m):
    rng = np.random.default_rng(7)
    p, loss, gamma = random_case(rng, b, m)
    got = np.asarray(k.asa_update(p, loss, gamma))
    np.testing.assert_allclose(got.sum(axis=-1), np.ones(b), rtol=1e-5)
    assert (got >= k.P_FLOOR / 2).all(), "floor must hold"


def test_update_degenerate_row_resets_to_uniform():
    m = 53
    p = jnp.full((1, m), 1.0 / m, dtype=jnp.float32)
    loss = jnp.full((1, m), 1.0, dtype=jnp.float32)
    gamma = jnp.array([200.0], dtype=jnp.float32)  # exp(-200) underflows f32
    got = np.asarray(k.asa_update(p, loss, gamma, block_b=1))
    np.testing.assert_allclose(got, np.full((1, m), 1.0 / m), rtol=1e-5)


def test_stats_matches_ref():
    rng = np.random.default_rng(11)
    p, _, _ = random_case(rng, 8, 53)
    values = jnp.array(rng.uniform(1.0, 1e5, size=(53,)).astype(np.float32))
    got = k.asa_stats(p, values, block_b=8)
    want = ref.asa_stats_ref(p, values)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_update_rejects_indivisible_batch():
    p = jnp.ones((6, 53), dtype=jnp.float32) / 53
    with pytest.raises(ValueError):
        k.asa_update(p, jnp.zeros_like(p), jnp.ones((6,), jnp.float32), block_b=8)


@settings(max_examples=40, deadline=None)
@given(
    b_pow=st.integers(min_value=0, max_value=3),
    m=st.integers(min_value=4, max_value=80),
    gamma_scale=st.floats(min_value=1e-3, max_value=10.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_update_matches_ref_hypothesis(b_pow, m, gamma_scale, seed):
    b = 2**b_pow
    rng = np.random.default_rng(seed)
    p = normalise(rng.uniform(1e-6, 1.0, size=(b, m)))
    loss = rng.uniform(0.0, 2.0, size=(b, m)).astype(np.float32)
    gamma = (rng.uniform(0.1, 1.0, size=(b,)) * gamma_scale).astype(np.float32)
    block = b if b <= 8 else 8
    got = np.asarray(k.asa_update(jnp.array(p), jnp.array(loss), jnp.array(gamma), block_b=block))
    want = np.asarray(ref.asa_update_ref(jnp.array(p), jnp.array(loss), jnp.array(gamma)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)
    assert np.isfinite(got).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_repeated_updates_concentrate_on_zero_loss_action(seed):
    rng = np.random.default_rng(seed)
    m = 53
    p = jnp.full((1, m), 1.0 / m, dtype=jnp.float32)
    loss = np.ones((1, m), dtype=np.float32)
    best = int(rng.integers(0, m))
    loss[0, best] = 0.0
    loss = jnp.array(loss)
    gamma = jnp.array([0.5], dtype=jnp.float32)
    for _ in range(60):
        p = k.asa_update(p, loss, gamma, block_b=1)
    assert int(np.argmax(np.asarray(p)[0])) == best
    assert np.asarray(p)[0, best] > 0.99
