"""L2 correctness: the full asa_step graph (shapes, semantics, AOT text)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_asa_step_shapes():
    for b in aot.BATCHES:
        args = model.example_args(b)
        new_p, stats = model.asa_step(*args)
        assert new_p.shape == (b, 53)
        assert stats.shape == (b, 3)


def test_asa_step_matches_ref_composition():
    rng = np.random.default_rng(3)
    b, m = 8, 53
    p = rng.uniform(1e-4, 1.0, size=(b, m)).astype(np.float32)
    p /= p.sum(axis=-1, keepdims=True)
    loss = rng.uniform(0, 1, size=(b, m)).astype(np.float32)
    gamma = rng.uniform(0.05, 2.0, size=(b,)).astype(np.float32)
    values = rng.uniform(1, 1e5, size=(m,)).astype(np.float32)
    new_p, stats = model.asa_step(
        jnp.array(p), jnp.array(loss), jnp.array(gamma), jnp.array(values)
    )
    want_p = ref.asa_update_ref(jnp.array(p), jnp.array(loss), jnp.array(gamma))
    want_stats = ref.asa_stats_ref(want_p, jnp.array(values))
    np.testing.assert_allclose(new_p, want_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(stats, want_stats, rtol=1e-4, atol=1e-4)


def test_stats_expected_wait_of_peaked_distribution():
    m = 53
    p = np.full((1, m), 1e-6, dtype=np.float32)
    p[0, 10] = 1.0
    p /= p.sum()
    values = np.arange(m, dtype=np.float32) * 100
    _, stats = model.asa_step(
        jnp.array(p),
        jnp.zeros((1, m), jnp.float32),
        jnp.zeros((1,), jnp.float32),
        jnp.array(values),
    )
    # Expected wait ≈ 1000 (the peaked action), entropy near 0, pmax near 1.
    assert abs(float(stats[0, 0]) - 1000.0) < 20.0
    assert float(stats[0, 1]) < 0.05
    assert float(stats[0, 2]) > 0.99


def test_aot_lowering_produces_hlo_text():
    lowered = jax.jit(model.asa_step).lower(*model.example_args(8))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[8,53]" in text


def test_aot_batches_cover_padding_strategy():
    # The rust runtime pads to the smallest variant that fits; the exported
    # set must be sorted and start at 1 so any batch is coverable.
    assert aot.BATCHES[0] == 1
    assert list(aot.BATCHES) == sorted(aot.BATCHES)
    assert aot.M == 53  # must match rust ActionGrid::paper()


def test_kernel_floor_matches_rust_constant():
    from compile.kernels import asa_update as k
    from compile.kernels import ref
    # One constant, three implementations (rust P_FLOOR is asserted in
    # rust tests against the artifact's behaviour).
    assert k.P_FLOOR == ref.P_FLOOR == 1e-6
