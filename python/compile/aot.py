"""AOT lowering: JAX/Pallas model -> HLO text artifacts for the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Batch-size variants exported; the rust runtime pads a batch to the
# smallest variant that fits (or loops the largest).
BATCHES = (1, 8, 64)
M = 53  # paper grid width; must match rust ActionGrid::paper()


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--m", type=int, default=M)
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"m": args.m, "variants": []}
    for batch in BATCHES:
        ex = model.example_args(batch, args.m)
        lowered = jax.jit(model.asa_step).lower(*ex)
        text = to_hlo_text(lowered)
        name = f"asa_step_b{batch}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["variants"].append({"batch": batch, "file": name})
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
