"""Layer 2 — the full batched ASA policy step as a JAX computation.

One invocation performs, for B tracked job geometries at once:

  1. the exponential-weights update (delegating to the L1 Pallas kernel), and
  2. the per-row summary statistics the coordinator reports (expected wait,
     entropy, max probability).

The function is lowered once by ``aot.py`` to HLO text and executed from the
rust runtime (``rust/src/runtime``) via PJRT — python never runs on the
request path. Batch-size variants {1, 8, 64} are exported so the rust side
pads at most to the next variant.
"""

import jax
import jax.numpy as jnp

from compile.kernels import asa_update as k


def asa_step(p, loss, gamma, values):
    """Full ASA policy step.

    Args:
      p:      f32[B, m] current distributions.
      loss:   f32[B, m] per-action losses.
      gamma:  f32[B]    learning rates.
      values: f32[m]    the action grid (seconds).

    Returns:
      (new_p f32[B,m], stats f32[B,3]) — stats rows are
      (expected wait, entropy, max probability) of the *updated* rows.
    """
    b = p.shape[0]
    block_b = 8 if b % 8 == 0 else 1
    new_p = k.asa_update(p, loss, gamma, block_b=block_b)
    stats = k.asa_stats(new_p, values, block_b=block_b)
    return new_p, stats


def example_args(batch, m=53):
    """Representative inputs used for AOT lowering (shapes/dtypes only)."""
    p = jnp.full((batch, m), 1.0 / m, dtype=jnp.float32)
    loss = jnp.zeros((batch, m), dtype=jnp.float32)
    gamma = jnp.ones((batch,), dtype=jnp.float32)
    values = jnp.arange(m, dtype=jnp.float32)
    return p, loss, gamma, values
