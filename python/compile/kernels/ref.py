"""Pure-jnp oracle for the L1 Pallas kernels.

The contract every backend must satisfy (rust PureRustKernel, the Pallas
kernel, and the AOT artifact): multiplicative update, degenerate-row reset
to uniform, probability floor, renormalisation.
"""

import jax.numpy as jnp

P_FLOOR = 1e-6


def asa_update_ref(p, loss, gamma):
    """Reference batched update. Shapes: p,loss f32[B,m]; gamma f32[B]."""
    w = p * jnp.exp(-gamma[:, None] * loss)
    norm = jnp.sum(w, axis=-1, keepdims=True)
    m = p.shape[-1]
    uniform = jnp.full_like(w, 1.0 / m)
    safe = norm > 0.0
    w = jnp.where(safe, w / jnp.where(safe, norm, 1.0), uniform)
    w = jnp.maximum(w, P_FLOOR)
    return w / jnp.sum(w, axis=-1, keepdims=True)


def asa_stats_ref(p, values):
    """Reference row stats: (expected wait, entropy, pmax) per row."""
    expected = jnp.sum(p * values[None, :], axis=-1)
    logp = jnp.log(jnp.maximum(p, 1e-30))
    entropy = -jnp.sum(p * logp, axis=-1)
    pmax = jnp.max(p, axis=-1)
    return jnp.stack([expected, entropy, pmax], axis=-1)
