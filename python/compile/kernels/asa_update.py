"""Layer 1 — the ASA exponential-weights update as a Pallas kernel.

The paper's Algorithm 1 line 7,

    p_{t+1,a}  <-  e^{-gamma_t * l_ta} * p_{t,a} / N_t ,

batched over B independent job geometries (rows), each with m waiting-time
alternatives, plus the probability floor the rust reference kernel applies
(see ``rust/src/coordinator/kernel.rs::P_FLOOR``).

TPU mapping (DESIGN.md §Hardware-Adaptation): this is VPU work, not MXU —
one geometry row per block row, the action axis padded to the 128-lane
dimension. The whole working set for a row update is `3·m` floats, so a
(block_b, m_pad) block stays comfortably in VMEM and the row reduction
(normalisation) happens inside one block without cross-block traffic.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same computation
runs under the rust runtime. Correctness against ``ref.py`` is enforced by
``python/tests/test_kernel.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Must match rust/src/coordinator/kernel.rs::P_FLOOR.
P_FLOOR = 1e-6


def _update_kernel(p_ref, loss_ref, gamma_ref, out_ref):
    """One block: rows of p, loss and per-row gamma -> updated rows."""
    p = p_ref[...]
    loss = loss_ref[...]
    gamma = gamma_ref[...]  # (block_b, 1)
    w = p * jnp.exp(-gamma * loss)
    norm = jnp.sum(w, axis=-1, keepdims=True)
    # Degenerate rows (all mass vanished) reset to uniform — same rule as
    # the rust reference kernel.
    m = p.shape[-1]
    uniform = jnp.full_like(w, 1.0 / m)
    safe = norm > 0.0
    w = jnp.where(safe, w / jnp.where(safe, norm, 1.0), uniform)
    # Probability floor + renormalise (keeps every alternative reachable).
    w = jnp.maximum(w, P_FLOOR)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out_ref[...] = w


@functools.partial(jax.jit, static_argnames=("block_b",))
def asa_update(p, loss, gamma, *, block_b=8):
    """Batched ASA probability update.

    Args:
      p:     f32[B, m]  current distributions (rows sum to 1).
      loss:  f32[B, m]  per-action losses for this round.
      gamma: f32[B]     per-row learning rate (non-increasing over rounds).
      block_b: rows per Pallas block.

    Returns:
      f32[B, m] updated, floored, renormalised distributions.
    """
    b, m = p.shape
    if b % block_b != 0:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")
    gamma_col = gamma.reshape(b, 1)
    grid = (b // block_b,)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), p.dtype),
        interpret=True,
    )(p, loss, gamma_col)


def _stats_kernel(p_ref, values_ref, out_ref):
    """Expected wait, entropy and max-probability per row."""
    p = p_ref[...]
    values = values_ref[...]  # (1, m) broadcast row
    expected = jnp.sum(p * values, axis=-1)
    logp = jnp.log(jnp.maximum(p, 1e-30))
    entropy = -jnp.sum(p * logp, axis=-1)
    pmax = jnp.max(p, axis=-1)
    out_ref[...] = jnp.stack([expected, entropy, pmax], axis=-1)


@functools.partial(jax.jit, static_argnames=("block_b",))
def asa_stats(p, values, *, block_b=8):
    """Per-row summary statistics of the distributions.

    Args:
      p:      f32[B, m] distributions.
      values: f32[m]    the action grid in seconds.

    Returns:
      f32[B, 3]: (expected wait, entropy, max probability) per row.
    """
    b, m = p.shape
    if b % block_b != 0:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")
    values_row = values.reshape(1, m)
    grid = (b // block_b,)
    return pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 3), p.dtype),
        interpret=True,
    )(p, values_row)
